#!/usr/bin/env python
"""Schema check for a BENCH_*.json file (run by the CI bench-smoke step).

  PYTHONPATH=src python tools/check_bench_json.py BENCH_range_query.json \\
      --schemes ebr,steam,dlrt,slrt,bbf --structures hash,tree --min-mixes 2

Fails (exit 1) if required top-level/row keys are missing, rows are empty,
requested scheme/structure coverage is absent, or any row reports snapshot
violations.  With ``--txn`` additionally validates the read-write-transaction
fields (schema v4, DESIGN.md §8-§10): ``txn_size``/``txn_ranges`` >= 1,
``rw_ratio`` and ``abort_rate`` in [0, 1], commit/abort counters consistent
with the rate, the abort-reason taxonomy (``aborts_footprint`` +
``aborts_wcc`` + ``aborts_capacity``) partitioning ``txns_aborted`` exactly,
at least ``--min-txn-sizes`` distinct write-set sizes with committed txns,
and the v4 abort ⇒ reclaim ⇒ retry fields: all four non-negative,
``reclaims_triggered`` <= ``aborts_capacity`` (only capacity aborts trigger
reclaims), ``reclaim_latency_slices`` >= ``reclaims_triggered`` (every
reclaim pass stalls at least one slice), and
``versions_reclaimed_on_abort``/``peak_space_post_reclaim`` zero when no
reclaim ever ran.
"""
from __future__ import annotations

import argparse
import json
import sys

from repro.core.sim.measure import validate_bench_payload


TXN_FIELDS = ("txn_size", "rw_ratio", "txns_committed", "txns_aborted",
              "abort_rate", "txn_ranges", "point_reads", "aborts_footprint",
              "aborts_wcc", "aborts_capacity", "txn_giveups",
              "backoff_slices", "reclaims_triggered",
              "versions_reclaimed_on_abort", "reclaim_latency_slices",
              "peak_space_post_reclaim")

RECLAIM_FIELDS = ("reclaims_triggered", "versions_reclaimed_on_abort",
                  "reclaim_latency_slices", "peak_space_post_reclaim")


def check_txn_fields(rows, min_txn_sizes: int):
    """Validate the schema-v4 read-write-txn row fields (DESIGN.md §8-§10)."""
    problems = []
    txn_rows = []
    for i, r in enumerate(rows):
        missing = [k for k in TXN_FIELDS if k not in r]
        if missing:
            problems.append(f"row {i} missing txn fields: {missing}")
            continue
        for f in ("rw_ratio", "abort_rate"):
            if not (0.0 <= r[f] <= 1.0):
                problems.append(f"row {i}: {f}={r[f]} outside [0, 1]")
        attempts = r["txns_committed"] + r["txns_aborted"]
        if attempts:
            txn_rows.append(r)
            if r["txn_size"] < 1:
                problems.append(f"row {i}: txns ran but txn_size="
                                f"{r['txn_size']} < 1")
            if r["txn_ranges"] < 1:
                problems.append(f"row {i}: txns ran but txn_ranges="
                                f"{r['txn_ranges']} < 1")
            if r["rw_ratio"] <= 0.0:
                problems.append(f"row {i}: txns ran but rw_ratio="
                                f"{r['rw_ratio']} <= 0")
            want = round(r["txns_aborted"] / attempts, 4)
            if abs(r["abort_rate"] - want) > 1e-4:
                problems.append(f"row {i}: abort_rate {r['abort_rate']} != "
                                f"aborted/attempts {want}")
            reasons = (r["aborts_footprint"] + r["aborts_wcc"]
                       + r["aborts_capacity"])
            if reasons != r["txns_aborted"]:
                problems.append(
                    f"row {i}: abort reasons sum to {reasons} but "
                    f"txns_aborted={r['txns_aborted']} (taxonomy must "
                    f"partition the aborts)")
        # schema v4: abort => reclaim => retry fields (DESIGN.md §10)
        for f in RECLAIM_FIELDS:
            if r[f] < 0:
                problems.append(f"row {i}: {f}={r[f]} < 0")
        if r["reclaims_triggered"] > r["aborts_capacity"]:
            problems.append(
                f"row {i}: reclaims_triggered={r['reclaims_triggered']} > "
                f"aborts_capacity={r['aborts_capacity']} (only capacity "
                f"aborts trigger reclaims)")
        if r["reclaim_latency_slices"] < r["reclaims_triggered"]:
            problems.append(
                f"row {i}: reclaim_latency_slices="
                f"{r['reclaim_latency_slices']} < reclaims_triggered="
                f"{r['reclaims_triggered']} (every reclaim pass stalls "
                f"at least one slice)")
        if r["reclaims_triggered"] == 0 and (
                r["versions_reclaimed_on_abort"] or
                r["peak_space_post_reclaim"]):
            problems.append(
                f"row {i}: reclaim outputs nonzero "
                f"(versions={r['versions_reclaimed_on_abort']}, "
                f"peak_post={r['peak_space_post_reclaim']}) with "
                f"reclaims_triggered=0")
    if not txn_rows:
        problems.append("--txn: no row has any committed or aborted txns")
    sizes = {r["txn_size"] for r in txn_rows}
    if len(sizes) < min_txn_sizes:
        problems.append(f"only {len(sizes)} distinct txn sizes ({sorted(sizes)}), "
                        f"need >= {min_txn_sizes}")
    return problems


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("path")
    ap.add_argument("--schemes", default="",
                    help="comma-separated schemes that must all appear")
    ap.add_argument("--structures", default="",
                    help="comma-separated structures that must all appear")
    ap.add_argument("--min-mixes", type=int, default=0,
                    help="minimum number of distinct operation mixes")
    ap.add_argument("--txn", action="store_true",
                    help="validate read-write-txn fields (txn benches)")
    ap.add_argument("--min-txn-sizes", type=int, default=1,
                    help="with --txn: minimum distinct txn write-set sizes")
    args = ap.parse_args()

    payload = json.load(open(args.path))
    problems = validate_bench_payload(payload)

    rows = payload.get("rows", [])
    if args.schemes:
        want = set(args.schemes.split(","))
        got = {r.get("scheme") for r in rows}
        if not want <= got:
            problems.append(f"missing schemes: {sorted(want - got)}")
    if args.structures:
        want = set(args.structures.split(","))
        got = {r.get("ds") for r in rows}
        if not want <= got:
            problems.append(f"missing structures: {sorted(want - got)}")
    if args.min_mixes:
        mixes = {r.get("mix") for r in rows}
        if len(mixes) < args.min_mixes:
            problems.append(f"only {len(mixes)} mixes present ({sorted(mixes)}), "
                            f"need >= {args.min_mixes}")
    bad = [r for r in rows if r.get("scan_violations", 0)]
    if bad:
        problems.append(f"{len(bad)} rows report snapshot violations")
    if args.txn:
        problems.extend(check_txn_fields(rows, args.min_txn_sizes))

    if problems:
        print(f"FAIL {args.path}:")
        for p in problems:
            print(f"  - {p}")
        return 1
    print(f"OK {args.path}: {len(rows)} rows, "
          f"{len({r['scheme'] for r in rows})} schemes, "
          f"{len({r['ds'] for r in rows})} structures, "
          f"{len({r['mix'] for r in rows})} mixes")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
