#!/usr/bin/env python
"""Schema check for a BENCH_*.json file (run by the CI bench-smoke step).

  PYTHONPATH=src python tools/check_bench_json.py BENCH_range_query.json \\
      --schemes ebr,steam,dlrt,slrt,bbf --structures hash,tree --min-mixes 2

The payload declares its row schema (``row_schema``; legacy payloads are
inferred from the bench name) and this tool dispatches on it: the base row
contract plus the schema's required row fields are validated by
``measure.validate_bench_payload``, then every invariant registered on the
schema runs (``measure.BenchSchema.invariants`` — txn rate/taxonomy
consistency, serve reclaim accounting, kernel roofline/speedup checks).
Adding a new bench means registering a schema in ``core/sim/measure.py``;
this tool needs no changes.

Fails (exit 1) if required keys are missing, rows are empty, requested
scheme/structure coverage is absent, any row reports snapshot violations, or
any schema invariant fails.  Strictness knobs (``--min-txn-sizes``,
``--require-pressure``, ``--min-speedup``) are forwarded to the invariants
via the options dict; ``--txn`` / ``--serve`` are kept as compatibility
aliases that assert the payload declares the matching schema.
"""
from __future__ import annotations

import argparse
import json

from repro.core.sim.measure import schema_of_payload, validate_bench_payload


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("path")
    ap.add_argument("--schemes", default="",
                    help="comma-separated schemes that must all appear")
    ap.add_argument("--structures", default="",
                    help="comma-separated structures that must all appear")
    ap.add_argument("--min-mixes", type=int, default=0,
                    help="minimum number of distinct operation mixes")
    ap.add_argument("--txn", action="store_true",
                    help="compat alias: assert the payload declares the "
                         "txn schema (its invariants run either way)")
    ap.add_argument("--min-txn-sizes", type=int, default=1,
                    help="txn schema: minimum distinct txn write-set sizes")
    ap.add_argument("--serve", action="store_true",
                    help="compat alias: assert the payload declares the "
                         "serve schema (its invariants run either way)")
    ap.add_argument("--require-pressure", action="store_true",
                    help="serve schema: the most-reclaiming tier must show "
                         "working pressure reclamation in a majority of "
                         "policy cells")
    ap.add_argument("--min-speedup", type=float, default=1.0,
                    help="kernel schema: minimum fused-over-unfused speedup "
                         "on standard/full-tier rows")
    args = ap.parse_args()

    payload = json.load(open(args.path))
    problems = validate_bench_payload(payload)
    schema = schema_of_payload(payload)
    for flag, want in (("txn", args.txn), ("serve", args.serve)):
        if want and schema.name != flag:
            problems.append(f"--{flag}: payload declares row schema "
                            f"{schema.name!r}, not {flag!r}")

    rows = payload.get("rows", [])
    if args.schemes:
        want = set(args.schemes.split(","))
        got = {r.get("scheme") for r in rows}
        if not want <= got:
            problems.append(f"missing schemes: {sorted(want - got)}")
    if args.structures:
        want = set(args.structures.split(","))
        got = {r.get("ds") for r in rows}
        if not want <= got:
            problems.append(f"missing structures: {sorted(want - got)}")
    if args.min_mixes:
        mixes = {r.get("mix") for r in rows}
        if len(mixes) < args.min_mixes:
            problems.append(f"only {len(mixes)} mixes present ({sorted(mixes)}), "
                            f"need >= {args.min_mixes}")
    bad = [r for r in rows if r.get("scan_violations", 0)]
    if bad:
        problems.append(f"{len(bad)} rows report snapshot violations")

    options = {
        "min_txn_sizes": args.min_txn_sizes,
        "require_pressure": args.require_pressure,
        "min_speedup": args.min_speedup,
    }
    for invariant in schema.invariants:
        problems.extend(invariant(rows, options))

    if problems:
        print(f"FAIL {args.path}:")
        for p in problems:
            print(f"  - {p}")
        return 1
    print(f"OK {args.path} [{schema.name}]: {len(rows)} rows, "
          f"{len({r['scheme'] for r in rows})} schemes, "
          f"{len({r['ds'] for r in rows})} structures, "
          f"{len({r['mix'] for r in rows})} mixes")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
