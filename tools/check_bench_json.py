#!/usr/bin/env python
"""Schema check for a BENCH_*.json file (run by the CI bench-smoke step).

  PYTHONPATH=src python tools/check_bench_json.py BENCH_range_query.json \\
      --schemes ebr,steam,dlrt,slrt,bbf --structures hash,tree --min-mixes 2

Fails (exit 1) if required top-level/row keys are missing, rows are empty,
requested scheme/structure coverage is absent, or any row reports snapshot
violations.  With ``--txn`` additionally validates the read-write-transaction
fields (schema v4, DESIGN.md §8-§10): ``txn_size``/``txn_ranges`` >= 1,
``rw_ratio`` and ``abort_rate`` in [0, 1], commit/abort counters consistent
with the rate, the abort-reason taxonomy (``aborts_footprint`` +
``aborts_wcc`` + ``aborts_capacity``) partitioning ``txns_aborted`` exactly,
at least ``--min-txn-sizes`` distinct write-set sizes with committed txns,
and the v4 abort ⇒ reclaim ⇒ retry fields: all four non-negative,
``reclaims_triggered`` <= ``aborts_capacity`` (only capacity aborts trigger
reclaims), ``reclaim_latency_slices`` >= ``reclaims_triggered`` (every
reclaim pass stalls at least one slice), and
``versions_reclaimed_on_abort``/``peak_space_post_reclaim`` zero when no
reclaim ever ran.
"""
from __future__ import annotations

import argparse
import json
import sys

from repro.core.sim.measure import validate_bench_payload


TXN_FIELDS = ("txn_size", "rw_ratio", "txns_committed", "txns_aborted",
              "abort_rate", "txn_ranges", "point_reads", "aborts_footprint",
              "aborts_wcc", "aborts_capacity", "txn_giveups",
              "backoff_slices", "reclaims_triggered",
              "versions_reclaimed_on_abort", "reclaim_latency_slices",
              "peak_space_post_reclaim")

RECLAIM_FIELDS = ("reclaims_triggered", "versions_reclaimed_on_abort",
                  "reclaim_latency_slices", "peak_space_post_reclaim")

SERVE_FIELDS = ("pressure_events", "pages_reclaimed", "peak_pages",
                "peak_pages_post_reclaim", "page_pool", "page_size",
                "decode_steps", "tokens_appended", "sequences_completed",
                "give_ups", "snapshot_pins", "overflow_count",
                "dropped_retires", "reclaims_triggered")


def check_serve_fields(rows, require_pressure: bool):
    """Validate BENCH_serve reclaim accounting (DESIGN.md §11): every
    reclaim pass was driven by a pressure event, the post-reclaim peak can
    never exceed the overall peak, and a cell that never reclaimed must
    report zero reclaim output.  With ``require_pressure``, the tier with
    the most reclaims must show the pressure loop actually working —
    reclaims > 0, pages freed > 0, post-reclaim peak < peak — in a
    majority of its policy cells."""
    problems = []
    for i, r in enumerate(rows):
        missing = [k for k in SERVE_FIELDS if k not in r]
        if missing:
            problems.append(f"row {i} missing serve fields: {missing}")
            continue
        for f in SERVE_FIELDS:
            if r[f] < 0:
                problems.append(f"row {i}: {f}={r[f]} < 0")
        if r["reclaims_triggered"] > r["pressure_events"]:
            problems.append(
                f"row {i}: reclaims_triggered={r['reclaims_triggered']} > "
                f"pressure_events={r['pressure_events']} (every reclaim "
                f"pass must be driven by a pressure event — the LWM rule)")
        if r["peak_pages_post_reclaim"] > r["peak_pages"]:
            problems.append(
                f"row {i}: peak_pages_post_reclaim="
                f"{r['peak_pages_post_reclaim']} > peak_pages="
                f"{r['peak_pages']}")
        if r["peak_pages"] > r["page_pool"]:
            problems.append(f"row {i}: peak_pages={r['peak_pages']} > "
                            f"page_pool={r['page_pool']}")
        if r["reclaims_triggered"] == 0 and (
                r["pages_reclaimed"] or r["peak_pages_post_reclaim"]):
            problems.append(
                f"row {i}: reclaim outputs nonzero (pages="
                f"{r['pages_reclaimed']}, peak_post="
                f"{r['peak_pages_post_reclaim']}) with reclaims_triggered=0")
        if r["peak_space_words"] != r["peak_pages"]:
            problems.append(
                f"row {i}: peak_space_words={r['peak_space_words']} != "
                f"peak_pages={r['peak_pages']} (serve rows measure space "
                f"in pages)")
    if require_pressure and not problems:
        serve_rows = [r for r in rows if "pressure_events" in r]
        by_fig = {}
        for r in serve_rows:
            by_fig.setdefault(r.get("figure"), []).append(r)
        fig, cells = max(
            by_fig.items(),
            key=lambda kv: sum(c["reclaims_triggered"] for c in kv[1]))
        good = [c for c in cells
                if c["reclaims_triggered"] > 0 and c["pages_reclaimed"] > 0
                and c["peak_pages_post_reclaim"] < c["peak_pages"]]
        if len(good) * 2 <= len(cells):
            problems.append(
                f"--require-pressure: only {len(good)}/{len(cells)} cells "
                f"of {fig} show working pressure reclamation (need a "
                f"majority with reclaims > 0, pages freed > 0, "
                f"post-reclaim peak < peak)")
    return problems


def check_txn_fields(rows, min_txn_sizes: int):
    """Validate the schema-v4 read-write-txn row fields (DESIGN.md §8-§10)."""
    problems = []
    txn_rows = []
    for i, r in enumerate(rows):
        missing = [k for k in TXN_FIELDS if k not in r]
        if missing:
            problems.append(f"row {i} missing txn fields: {missing}")
            continue
        for f in ("rw_ratio", "abort_rate"):
            if not (0.0 <= r[f] <= 1.0):
                problems.append(f"row {i}: {f}={r[f]} outside [0, 1]")
        attempts = r["txns_committed"] + r["txns_aborted"]
        if attempts:
            txn_rows.append(r)
            if r["txn_size"] < 1:
                problems.append(f"row {i}: txns ran but txn_size="
                                f"{r['txn_size']} < 1")
            if r["txn_ranges"] < 1:
                problems.append(f"row {i}: txns ran but txn_ranges="
                                f"{r['txn_ranges']} < 1")
            if r["rw_ratio"] <= 0.0:
                problems.append(f"row {i}: txns ran but rw_ratio="
                                f"{r['rw_ratio']} <= 0")
            want = round(r["txns_aborted"] / attempts, 4)
            if abs(r["abort_rate"] - want) > 1e-4:
                problems.append(f"row {i}: abort_rate {r['abort_rate']} != "
                                f"aborted/attempts {want}")
            reasons = (r["aborts_footprint"] + r["aborts_wcc"]
                       + r["aborts_capacity"])
            if reasons != r["txns_aborted"]:
                problems.append(
                    f"row {i}: abort reasons sum to {reasons} but "
                    f"txns_aborted={r['txns_aborted']} (taxonomy must "
                    f"partition the aborts)")
        # schema v4: abort => reclaim => retry fields (DESIGN.md §10)
        for f in RECLAIM_FIELDS:
            if r[f] < 0:
                problems.append(f"row {i}: {f}={r[f]} < 0")
        if r["reclaims_triggered"] > r["aborts_capacity"]:
            problems.append(
                f"row {i}: reclaims_triggered={r['reclaims_triggered']} > "
                f"aborts_capacity={r['aborts_capacity']} (only capacity "
                f"aborts trigger reclaims)")
        if r["reclaim_latency_slices"] < r["reclaims_triggered"]:
            problems.append(
                f"row {i}: reclaim_latency_slices="
                f"{r['reclaim_latency_slices']} < reclaims_triggered="
                f"{r['reclaims_triggered']} (every reclaim pass stalls "
                f"at least one slice)")
        if r["reclaims_triggered"] == 0 and (
                r["versions_reclaimed_on_abort"] or
                r["peak_space_post_reclaim"]):
            problems.append(
                f"row {i}: reclaim outputs nonzero "
                f"(versions={r['versions_reclaimed_on_abort']}, "
                f"peak_post={r['peak_space_post_reclaim']}) with "
                f"reclaims_triggered=0")
    if not txn_rows:
        problems.append("--txn: no row has any committed or aborted txns")
    sizes = {r["txn_size"] for r in txn_rows}
    if len(sizes) < min_txn_sizes:
        problems.append(f"only {len(sizes)} distinct txn sizes ({sorted(sizes)}), "
                        f"need >= {min_txn_sizes}")
    return problems


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("path")
    ap.add_argument("--schemes", default="",
                    help="comma-separated schemes that must all appear")
    ap.add_argument("--structures", default="",
                    help="comma-separated structures that must all appear")
    ap.add_argument("--min-mixes", type=int, default=0,
                    help="minimum number of distinct operation mixes")
    ap.add_argument("--txn", action="store_true",
                    help="validate read-write-txn fields (txn benches)")
    ap.add_argument("--min-txn-sizes", type=int, default=1,
                    help="with --txn: minimum distinct txn write-set sizes")
    ap.add_argument("--serve", action="store_true",
                    help="validate serve-bench reclaim accounting "
                         "(BENCH_serve rows)")
    ap.add_argument("--require-pressure", action="store_true",
                    help="with --serve: the most-reclaiming tier must show "
                         "working pressure reclamation in a majority of "
                         "policy cells")
    args = ap.parse_args()

    payload = json.load(open(args.path))
    problems = validate_bench_payload(payload)

    rows = payload.get("rows", [])
    if args.schemes:
        want = set(args.schemes.split(","))
        got = {r.get("scheme") for r in rows}
        if not want <= got:
            problems.append(f"missing schemes: {sorted(want - got)}")
    if args.structures:
        want = set(args.structures.split(","))
        got = {r.get("ds") for r in rows}
        if not want <= got:
            problems.append(f"missing structures: {sorted(want - got)}")
    if args.min_mixes:
        mixes = {r.get("mix") for r in rows}
        if len(mixes) < args.min_mixes:
            problems.append(f"only {len(mixes)} mixes present ({sorted(mixes)}), "
                            f"need >= {args.min_mixes}")
    bad = [r for r in rows if r.get("scan_violations", 0)]
    if bad:
        problems.append(f"{len(bad)} rows report snapshot violations")
    if args.txn:
        problems.extend(check_txn_fields(rows, args.min_txn_sizes))
    if args.serve:
        problems.extend(check_serve_fields(rows, args.require_pressure))

    if problems:
        print(f"FAIL {args.path}:")
        for p in problems:
            print(f"  - {p}")
        return 1
    print(f"OK {args.path}: {len(rows)} rows, "
          f"{len({r['scheme'] for r in rows})} schemes, "
          f"{len({r['ds'] for r in rows})} structures, "
          f"{len({r['mix'] for r in rows})} mixes")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
