#!/usr/bin/env python
"""Schema check for a BENCH_*.json file (run by the CI bench-smoke step).

  PYTHONPATH=src python tools/check_bench_json.py BENCH_range_query.json \\
      --schemes ebr,steam,dlrt,slrt,bbf --structures hash,tree --min-mixes 2

Fails (exit 1) if required top-level/row keys are missing, rows are empty,
requested scheme/structure coverage is absent, or any row reports snapshot
violations.
"""
from __future__ import annotations

import argparse
import json
import sys

from repro.core.sim.measure import validate_bench_payload


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("path")
    ap.add_argument("--schemes", default="",
                    help="comma-separated schemes that must all appear")
    ap.add_argument("--structures", default="",
                    help="comma-separated structures that must all appear")
    ap.add_argument("--min-mixes", type=int, default=0,
                    help="minimum number of distinct operation mixes")
    args = ap.parse_args()

    payload = json.load(open(args.path))
    problems = validate_bench_payload(payload)

    rows = payload.get("rows", [])
    if args.schemes:
        want = set(args.schemes.split(","))
        got = {r.get("scheme") for r in rows}
        if not want <= got:
            problems.append(f"missing schemes: {sorted(want - got)}")
    if args.structures:
        want = set(args.structures.split(","))
        got = {r.get("ds") for r in rows}
        if not want <= got:
            problems.append(f"missing structures: {sorted(want - got)}")
    if args.min_mixes:
        mixes = {r.get("mix") for r in rows}
        if len(mixes) < args.min_mixes:
            problems.append(f"only {len(mixes)} mixes present ({sorted(mixes)}), "
                            f"need >= {args.min_mixes}")
    bad = [r for r in rows if r.get("scan_violations", 0)]
    if bad:
        problems.append(f"{len(bad)} rows report snapshot violations")

    if problems:
        print(f"FAIL {args.path}:")
        for p in problems:
            print(f"  - {p}")
        return 1
    print(f"OK {args.path}: {len(rows)} rows, "
          f"{len({r['scheme'] for r in rows})} schemes, "
          f"{len({r['ds'] for r in rows})} structures, "
          f"{len({r['mix'] for r in rows})} mixes")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
